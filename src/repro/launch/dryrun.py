import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh).

Proves the distribution config is coherent without real hardware: 512
placeholder CPU devices stand in for 2 TPU v5e pods.  For each pair we
record ``compiled.memory_analysis()`` (fits-per-device proof),
``compiled.cost_analysis()`` (FLOPs/bytes) and the collective traffic
parsed from the post-SPMD HLO — the three §Roofline terms derive from
these (benchmarks/roofline.py).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.config import SHAPES                     # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import all_pairs, build_lowering  # noqa: E402

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "s8": 1, "s16": 2, "s32": 4, "s64": 8,
    "u4": 0.5, "u8": 1, "u16": 2, "u32": 4, "u64": 8,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _result_bytes(line: str) -> float:
    """Bytes of the op's result (post-SPMD per-device shape).  Tuples
    (e.g. fused all-reduces) sum their elements."""
    lhs = line.split(" = ", 1)[1] if " = " in line else line
    # only look at the result type: everything before the op name call
    head = lhs.split("(", 1)[0]
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(head):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _IOTA_GROUPS_RE.search(line)   # iota replica group list [n,m]
    if m:
        return int(m.group(2))
    return 2


def collective_stats(hlo_text: str) -> dict:
    """Per-device collective traffic estimate from optimized HLO.

    Ring-model traffic per device given the per-device result bytes R
    and group size n:  all-gather (n−1)/n·R, all-reduce 2(n−1)/n·R,
    reduce-scatter (n−1)·R, all-to-all (n−1)/n·R, permute R.
    """
    kinds = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
             "all-to-all": 0.0, "collective-permute": 0.0}
    counts = {k: 0 for k in kinds}
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("%") or " = " in s:
            for kind in kinds:
                # match op invocation, not metadata mentions
                if re.search(rf"\)?\s{kind}(-start)?\(", s) or\
                        re.search(rf"= \S+ {kind}(-start)?\(", s):
                    r = _result_bytes(s)
                    n = _group_size(s)
                    if kind == "all-gather":
                        t = r * (n - 1) / n
                    elif kind == "all-reduce":
                        t = 2 * r * (n - 1) / n
                    elif kind == "reduce-scatter":
                        t = r * (n - 1)
                    elif kind == "all-to-all":
                        t = r * (n - 1) / n
                    else:
                        t = r
                    kinds[kind] += t
                    counts[kind] += 1
                    break
    total = sum(kinds.values())
    return {"bytes_per_device": total, "by_kind": kinds, "counts": counts}


def print_whales(hlo_text: str, top: int = 12) -> None:
    """Largest per-device tensor shapes in the optimized HLO (debug aid
    for memory hillclimbs — identifies what dominates temp bytes)."""
    sizes = {}
    for m in _SHAPE_RE.finditer(hlo_text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * _DTYPE_BYTES[dt]
        if b > 2 ** 27:
            key = f"{dt}[{dims}]"
            cnt = sizes.get(key, (0, 0))[1]
            sizes[key] = (b, cnt + 1)
    for k, (b, cnt) in sorted(sizes.items(), key=lambda kv: -kv[1][0])[:top]:
        print(f"   whale {b / 2**30:8.2f} GiB x{cnt:4d}  {k}")


def run_one(arch: str, shape: str, multi_pod: bool, out_dir: str,
            save_hlo: bool = False, whales: bool = False,
            variant: str = "baseline") -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    mesh = make_production_mesh(multi_pod=multi_pod)
    if variant == "w8kv8":
        from repro.launch.specs import build_quantized_decode
        low = build_quantized_decode(arch, shape, mesh)
        mesh_name += "+w8kv8"
    else:
        low = build_lowering(arch, shape, mesh)
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
           "kind": low.kind, "n_devices": mesh.size}
    if low.skip:
        rec["skipped"] = low.skip
        print(f"[dryrun] {arch} × {shape} × {mesh_name}: SKIP ({low.skip})")
        return rec

    t0 = time.perf_counter()
    from jax.sharding import NamedSharding, PartitionSpec
    in_shard = jax.tree.map(
        lambda s: NamedSharding(mesh, s), low.in_specs,
        is_leaf=lambda x: isinstance(x, PartitionSpec))
    with jax.set_mesh(mesh):
        jitted = jax.jit(low.step_fn, in_shardings=in_shard,
                         donate_argnums=low.donate)
        lowered = jitted.lower(*low.args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    coll = collective_stats(hlo)

    # trip-count-aware re-derivation (cost_analysis counts a while body
    # once regardless of its trip count — see launch/hlo_analysis.py)
    from repro.launch.hlo_analysis import analyze
    hlo_costs = analyze(hlo)

    rec.update({
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": float(cost.get("flops", 0.0)),
        "bytes_accessed_per_device": float(cost.get("bytes accessed", 0.0)),
        "hlo_flops_per_device": hlo_costs.flops,
        "hlo_bytes_per_device": hlo_costs.bytes,
        "hlo_collective_bytes_per_device": hlo_costs.coll_bytes,
        "hlo_collective_by_kind": hlo_costs.coll_by_kind,
        "hlo_collective_counts": hlo_costs.coll_counts,
        "collective": coll,
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
            "code_bytes": int(getattr(
                mem, "generated_code_size_in_bytes", 0)),
        },
    })
    arg_b = rec["memory"]["argument_bytes"]
    tmp_b = rec["memory"]["temp_bytes"]
    print(f"[dryrun] {arch} × {shape} × {mesh_name}: OK  "
          f"compile={t_compile:.1f}s  args={arg_b / 2**30:.2f}GiB  "
          f"temp={tmp_b / 2**30:.2f}GiB  "
          f"flops/dev={hlo_costs.flops:.3e}  "
          f"bytes/dev={hlo_costs.bytes:.3e}  "
          f"coll={hlo_costs.coll_bytes / 2**30:.3f}GiB")
    print(f"         memory_analysis: {mem}")
    if whales:
        print_whales(hlo)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        name = f"{arch}__{shape}__{mesh_name}.json"
        with open(os.path.join(out_dir, name), "w") as f:
            json.dump(rec, f, indent=1)
        if save_hlo:
            with open(os.path.join(out_dir, name[:-5] + ".hlo.txt"),
                      "w") as f:
                f.write(hlo)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--whales", action="store_true",
                    help="print the largest per-device HLO tensors")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "w8kv8"])
    args = ap.parse_args()

    pairs = list(all_pairs()) if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in pairs:
        for mp in meshes:
            try:
                run_one(arch, shape, mp, args.out, args.save_hlo,
                        args.whales, args.variant)
            except Exception as e:   # noqa: BLE001
                failures.append((arch, shape, mp, repr(e)))
                print(f"[dryrun] {arch} × {shape} × "
                      f"{'2x16x16' if mp else '16x16'}: FAIL {e!r}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        return 1
    print("\nall dry-runs passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
