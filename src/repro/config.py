"""Configuration dataclasses for the repro framework.

Every model served or trained by the system is described by a frozen
``ModelConfig``.  Architectures are registered in ``repro.configs`` (one
module per assigned architecture) and resolved through
``repro.models.registry``.

The TPU adaptation pads attention-head geometry so tensor-parallel
sharding over a fixed ``model`` mesh axis is always exact (see
DESIGN.md §2).  The *logical* config keeps the paper-exact head counts;
``tp_geometry`` derives the padded layout.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# hardware constants (TPU v5e, per chip) — used by the cost model & roofline
# ---------------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12      # 197 TFLOP/s bf16
HBM_BW = 819e9                # 819 GB/s
ICI_BW = 50e9                 # ~50 GB/s per link
HBM_BYTES = 16 * 1024**3      # 16 GiB HBM per v5e chip

# KV-cache pool granularity: one head-wise block holds BLOCK_TOKENS tokens
# of a single KV head (paper §3.4: "each block holds the KV cache of one
# head for several tokens").
BLOCK_TOKENS = 16


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden size
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    d_state: int                   # N — SSM state size
    head_dim: int = 64             # P — channels per SSM head
    expand: int = 2                # d_inner = expand * d_model
    conv_kernel: int = 4
    chunk_size: int = 256          # Q — SSD chunk length
    n_groups: int = 1              # B/C groups


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 → d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    sliding_window: Optional[int] = None   # decode-time window (long_500k)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid layout: an attention block is applied after every
    # ``attn_every`` SSM layers (0 → no attention at all, pure SSM).
    attn_every: int = 0
    shared_attn: bool = False      # Zamba2-style: one shared attn block
    # modality frontend stub: number of embedding-input channels.  When
    # not None the model accepts precomputed frame/patch embeddings of
    # shape [batch, n_prefix, frontend_dim] in addition to tokens.
    frontend_dim: Optional[int] = None
    n_prefix_tokens: int = 0
    source: str = ""               # citation

    # ---- derived ---------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def n_attn_layers(self) -> int:
        if self.family == "ssm":
            return 0
        if self.family == "hybrid":
            if self.attn_every <= 0:
                return 0
            return self.n_layers // self.attn_every
        return self.n_layers

    @property
    def n_ssm_layers(self) -> int:
        if self.family == "ssm":
            return self.n_layers
        if self.family == "hybrid":
            return self.n_layers
        return 0

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm.head_dim if self.ssm else 0

    def param_count(self) -> int:
        """Analytic parameter count (exact for our implementation)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        hd = self.hd
        n_emb = v * d * (1 if self.tie_embeddings else 2)
        per_attn = d * (self.n_heads * hd) + d * (2 * self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        if self.qkv_bias:
            per_attn += (self.n_heads + 2 * self.n_kv_heads) * hd
        if self.qk_norm:
            per_attn += 2 * hd
        per_mlp = 3 * d * f
        if self.moe:
            per_mlp = self.moe.n_experts * 3 * d * self.moe.d_expert \
                + d * self.moe.n_experts
        per_ssm = 0
        if self.ssm:
            di, N, H = self.d_inner, self.ssm.d_state, self.n_ssm_heads
            G = self.ssm.n_groups
            in_proj = d * (2 * di + 2 * G * N + H)
            conv = (di + 2 * G * N) * self.ssm.conv_kernel
            out = di * d
            per_ssm = in_proj + conv + out + 3 * H + di  # A, D, dt_bias, gnorm
        total = n_emb + 2 * d  # final norm (w only; +d slack)
        if self.family == "ssm":
            total += L * (per_ssm + d)
        elif self.family == "hybrid":
            total += L * (per_ssm + d)
            n_attn = self.n_attn_layers if not self.shared_attn else 1
            total += n_attn * (per_attn + per_mlp + 2 * d)
        else:
            total += L * (per_attn + per_mlp + 2 * d)
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.moe:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        dense = self.param_count() - L * self.moe.n_experts * 3 * d * self.moe.d_expert
        return int(dense + L * self.moe.top_k * 3 * d * self.moe.d_expert)

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        """KV-cache bytes per token (logical, un-padded)."""
        return 2 * self.n_attn_layers * self.n_kv_heads * self.hd * dtype_bytes

    def weight_bytes(self, dtype_bytes: int = 2) -> int:
        return self.param_count() * dtype_bytes


@dataclass(frozen=True)
class TPGeometry:
    """Padded attention geometry for an exact tensor-parallel layout.

    ``kv_padded = n_kv * 16/gcd(n_kv,16)`` is divisible by ``tp``;
    each physical kv head appears ``rep`` times.  Query heads are padded
    so every kv-head replica carries the same number of query heads.
    Padding cost is real compute/memory waste and is surfaced in the
    roofline's useful-FLOPs ratio (DESIGN.md §2).
    """
    tp: int
    n_heads: int          # logical q heads
    n_kv_heads: int       # logical kv heads
    h_padded: int         # padded q heads (divisible by tp and kv_padded)
    kv_padded: int        # padded/replicated kv heads (divisible by tp)
    rep: int              # kv replication factor
    q_per_rank: int
    kv_per_rank: int
    group: int            # q heads per padded kv head


def tp_geometry(n_heads: int, n_kv_heads: int, tp: int = 16) -> TPGeometry:
    g = math.gcd(n_kv_heads, tp)
    rep = tp // g
    kv_padded = n_kv_heads * rep
    group_logical = n_heads // n_kv_heads
    group = max(1, math.ceil(group_logical / rep))
    h_padded = kv_padded * group
    # ensure divisibility by tp (kv_padded already divisible by tp)
    assert kv_padded % tp == 0 and h_padded % tp == 0
    return TPGeometry(
        tp=tp, n_heads=n_heads, n_kv_heads=n_kv_heads,
        h_padded=h_padded, kv_padded=kv_padded, rep=rep,
        q_per_rank=h_padded // tp, kv_per_rank=kv_padded // tp,
        group=group,
    )


def pad_vocab(v: int, multiple: int = 256) -> int:
    return ((v + multiple - 1) // multiple) * multiple


# ---------------------------------------------------------------------------
# input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> Tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def dp(self) -> int:       # total data-parallel ways (pod × data)
        return self.n_devices // 16

    @property
    def tp(self) -> int:
        return 16


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)
