"""Jit-hazard pass: keep jitted step impls retrace- and sync-free.

The fused tick's zero-retrace guarantee (DESIGN.md §5) holds only if
the functions under ``jax.jit`` never leave the traced world.  A
function is *jitted* when its name ends in ``_impl`` (the
``engine.jitted_step`` registry convention) or when it is passed —
directly or through ``functools.partial`` — to a ``jax.jit(...)`` call
in the same module.

Inside a jitted function, positional parameters are traced values
(keyword-only parameters after ``*`` are the static-config convention:
``partial(impl, cfg=cfg)`` binds them before jit).  Tracedness
propagates through simple assignments.  Flagged hazards:

* ``.item()`` anywhere — a host sync by definition;
* ``int()`` / ``float()`` / ``bool()`` / ``len()`` *of a traced
  value* — concretization errors at trace time, or silent host syncs;
* ``np.asarray`` / ``np.array`` — numpy forces the traced value onto
  the host;
* Python ``if`` / ``while`` / ternary on a traced value — a data-
  dependent Python branch retraces per branch arm (use ``jnp.where``
  / ``lax.cond``);
* ``print`` — executes at trace time only, and its presence usually
  means someone debugged a traced value through the host.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Set

from tools.muxlint.core import Finding, Source, register

HOST_CASTS = {"int", "float", "bool", "len"}
NP_ALIASES = {"np", "numpy"}
NP_SYNCS = {"asarray", "array"}


def _jit_target_names(tree: ast.AST) -> Set[str]:
    """Names passed to ``jax.jit(...)`` (directly or via
    ``partial(fn, ...)``) anywhere in the module."""
    out: Set[str] = set()

    def name_args(call: ast.Call) -> List[str]:
        names = []
        for a in call.args:
            if isinstance(a, ast.Name):
                names.append(a.id)
            elif isinstance(a, ast.Call):            # partial(fn, ...)
                names.extend(name_args(a))
        return names

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            f = node.func
            is_jit = (isinstance(f, ast.Attribute) and f.attr == "jit") \
                or (isinstance(f, ast.Name) and f.id == "jit")
            if is_jit:
                out.update(name_args(node))
    return out


def _jitted_functions(tree: ast.AST) -> List[ast.FunctionDef]:
    targets = _jit_target_names(tree)
    return [n for n in ast.walk(tree)
            if isinstance(n, ast.FunctionDef)
            and (n.name.endswith("_impl") or n.name in targets)]


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _traced_names(fn: ast.FunctionDef) -> Set[str]:
    """Positional params, plus names assigned from traced expressions
    (one forward pass — good enough for straight-line step impls)."""
    traced = {a.arg for a in fn.args.args + fn.args.posonlyargs}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and _names_in(node.value) & traced:
            for tgt in node.targets:
                traced |= {n.id for n in ast.walk(tgt)
                           if isinstance(n, ast.Name)}
        elif isinstance(node, ast.AugAssign) \
                and isinstance(node.target, ast.Name) \
                and _names_in(node.value) & traced:
            traced.add(node.target.id)
    return traced


@register("jit-hazard")
def check(src: Source) -> Iterable[Finding]:
    for fn in _jitted_functions(src.tree):
        traced = _traced_names(fn)

        def touches_traced(node: ast.AST) -> bool:
            return bool(_names_in(node) & traced)

        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                f = node.func
                if isinstance(f, ast.Attribute) and f.attr == "item":
                    yield src.finding(
                        "jit-hazard", node,
                        f"`.item()` inside jitted `{fn.name}` is a "
                        f"host sync")
                elif isinstance(f, ast.Name) and f.id == "print":
                    yield src.finding(
                        "jit-hazard", node,
                        f"`print` inside jitted `{fn.name}` runs at "
                        f"trace time only (use jax.debug.print)")
                elif isinstance(f, ast.Name) and f.id in HOST_CASTS \
                        and node.args and touches_traced(node.args[0]):
                    yield src.finding(
                        "jit-hazard", node,
                        f"`{f.id}()` on a traced value inside jitted "
                        f"`{fn.name}` concretizes at trace time")
                elif isinstance(f, ast.Attribute) \
                        and isinstance(f.value, ast.Name) \
                        and f.value.id in NP_ALIASES \
                        and f.attr in NP_SYNCS and touches_traced(node):
                    yield src.finding(
                        "jit-hazard", node,
                        f"`np.{f.attr}` on a traced value inside "
                        f"jitted `{fn.name}` forces a host transfer "
                        f"(use jnp)")
            elif isinstance(node, (ast.If, ast.While)) \
                    and touches_traced(node.test):
                kw = "if" if isinstance(node, ast.If) else "while"
                yield src.finding(
                    "jit-hazard", node,
                    f"Python `{kw}` on a traced value inside jitted "
                    f"`{fn.name}` — each arm retraces (use jnp.where "
                    f"/ lax.cond)")
            elif isinstance(node, ast.IfExp) and touches_traced(node.test):
                yield src.finding(
                    "jit-hazard", node,
                    f"ternary on a traced value inside jitted "
                    f"`{fn.name}` — use jnp.where")
