"""Clock-purity pass: deterministic-replay modules stay replayable.

Scope: the ``serving`` and ``core`` layers — everything a
``LogicalClock`` run flows through.  Bit-reproducible replay (DESIGN.md
§9) breaks the moment one of these modules reads a wall clock or draws
from nondeterministically-seeded randomness, so:

* ``clock`` — any reference to ``time.time`` / ``time.perf_counter`` /
  ``time.monotonic`` (and their ``_ns`` twins), whether called or
  passed around as a default, is flagged.  References, not just calls:
  a ``clock=time.perf_counter`` default is a deferred wall-clock read.
  The one structural exemption is code inside a class named
  ``WallClock`` — the single module that is *supposed* to own wall
  time; every other legitimate site (solo-probe calibration, wall-
  seconds reporting) must carry an inline justification or a baseline
  entry.
* ``rng`` — ``np.random.default_rng()`` with no seed, the legacy
  module-level ``np.random.*`` draws (global hidden state), and
  unseeded ``random.Random()`` / ``random.random()``-family calls.
  ``jax.random`` is key-passing and exempt by construction.

This is the pass that catches the ``time.time`` vs ``perf_counter``
drift class (launch/dryrun.py had exactly that skew before PR 10).
"""
from __future__ import annotations

import ast
from typing import Iterable, Set

from tools.muxlint.core import Finding, Source, register
from tools.muxlint.layering import layer_of_path

SCOPED_LAYERS = {"serving", "core"}
WALL_CLOCK_ATTRS = {"time", "perf_counter", "monotonic",
                    "time_ns", "perf_counter_ns", "monotonic_ns"}
NP_GLOBAL_DRAWS = {"random", "rand", "randn", "randint", "normal",
                   "uniform", "choice", "shuffle", "permutation",
                   "poisson", "exponential", "seed"}
PY_RANDOM_FNS = {"random", "randint", "randrange", "uniform", "choice",
                 "shuffle", "gauss", "sample"}


def _attr_chain(node: ast.AST):
    """Dotted name of an attribute chain, e.g. ``np.random.default_rng``
    -> ("np", "random", "default_rng"); None for non-name chains."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return None


def _wallclock_lines(tree: ast.AST) -> Set[int]:
    """Lines inside any ``class WallClock`` body (structural allow)."""
    lines: Set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "WallClock":
            end = getattr(node, "end_lineno", node.lineno)
            lines.update(range(node.lineno, end + 1))
    return lines


@register("purity")
def check(src: Source) -> Iterable[Finding]:
    if layer_of_path(src.path) not in SCOPED_LAYERS:
        return
    allowed = _wallclock_lines(src.tree)
    for node in ast.walk(src.tree):
        # -- wall-clock references -------------------------------------
        if isinstance(node, ast.Attribute):
            chain = _attr_chain(node)
            if (chain and len(chain) == 2 and chain[0] == "time"
                    and chain[1] in WALL_CLOCK_ATTRS
                    and node.lineno not in allowed):
                yield src.finding(
                    "clock", node,
                    f"wall-clock reference `time.{chain[1]}` in a "
                    f"deterministic-replay module — inject the unit "
                    f"clock (MuxScheduler.clock) instead")
        elif isinstance(node, ast.ImportFrom) and node.module == "time":
            for a in node.names:
                if a.name in WALL_CLOCK_ATTRS:
                    yield src.finding(
                        "clock", node,
                        f"`from time import {a.name}` in a "
                        f"deterministic-replay module")
        # -- randomness ------------------------------------------------
        elif isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            if not chain:
                continue
            tail = chain[-1]
            if chain[-2:] == ("random", "default_rng") \
                    and not node.args and not node.keywords:
                yield src.finding(
                    "rng", node,
                    "unseeded `default_rng()` — pass an explicit seed "
                    "so replay is reproducible")
            elif len(chain) >= 2 and chain[-2] == "random" \
                    and chain[0] in ("np", "numpy") \
                    and tail in NP_GLOBAL_DRAWS:
                yield src.finding(
                    "rng", node,
                    f"legacy global-state draw `np.random.{tail}` — "
                    f"use a seeded Generator")
            elif chain[0] == "random" and len(chain) == 2 \
                    and (tail in PY_RANDOM_FNS
                         or (tail == "Random" and not node.args)):
                yield src.finding(
                    "rng", node,
                    f"stdlib `random.{tail}` draws from hidden global "
                    f"state — use a seeded Generator")
