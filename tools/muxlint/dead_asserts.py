"""Dead-assert pass: an assert that cannot fire guards nothing.

The motivating find: ``assert cfg.attn_free or cfg.hd == self.head_dim
or True`` (kvcache.py pre-PR-10) — a tautology that silently disabled
head-dim validation on pool view registration.  Flagged classes:

* tautology   — an ``or``-arm that is a truthy constant makes the
                whole test unfalsifiable;
* self-compare — ``assert x == x`` (also ``<=``, ``>=``, ``is``);
* constant     — ``assert True`` / ``assert 1`` (``assert False`` is
                 a legitimate unreachable-branch sentinel and is not
                 flagged);
* tuple        — ``assert (cond, "msg")`` is a non-empty tuple, hence
                 always true (the classic parenthesized-assert typo);
* side-effect  — a mutating call (``.pop``/``.add``/…) or a walrus
                 inside the test: ``python -O`` strips asserts, so the
                 mutation silently disappears in optimized runs.
"""
from __future__ import annotations

import ast
from typing import Iterable

from tools.muxlint.core import Finding, Source, register

MUTATORS = {"pop", "popleft", "append", "appendleft", "add", "remove",
            "discard", "clear", "update", "setdefault", "extend",
            "insert", "write", "sort", "reverse"}
SELF_COMPARE_OPS = (ast.Eq, ast.LtE, ast.GtE, ast.Is)


def _truthy_const(node: ast.AST) -> bool:
    return isinstance(node, ast.Constant) and bool(node.value) \
        and not isinstance(node.value, str)


@register("dead-assert")
def check(src: Source) -> Iterable[Finding]:
    for node in ast.walk(src.tree):
        if not isinstance(node, ast.Assert):
            continue
        test = node.test
        if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.Or) \
                and any(_truthy_const(v) for v in test.values):
            yield src.finding(
                "dead-assert", node,
                "tautological assert: an `or <truthy constant>` arm "
                "makes the test always pass")
        elif isinstance(test, ast.Compare) and len(test.ops) == 1 \
                and isinstance(test.ops[0], SELF_COMPARE_OPS) \
                and ast.dump(test.left) == ast.dump(test.comparators[0]):
            yield src.finding(
                "dead-assert", node,
                "self-comparison assert always passes")
        elif _truthy_const(test):
            yield src.finding(
                "dead-assert", node,
                "assert on a truthy constant never fires")
        elif isinstance(test, ast.Tuple) and test.elts:
            yield src.finding(
                "dead-assert", node,
                "assert on a non-empty tuple is always true — did you "
                "mean `assert cond, msg`?")
        for sub in ast.walk(test):
            if isinstance(sub, ast.NamedExpr):
                yield src.finding(
                    "dead-assert", node,
                    "walrus inside an assert: the binding vanishes "
                    "under `python -O`")
                break
            if isinstance(sub, ast.Call) \
                    and isinstance(sub.func, ast.Attribute) \
                    and sub.func.attr in MUTATORS:
                yield src.finding(
                    "dead-assert", node,
                    f"side-effecting assert: `.{sub.func.attr}()` in "
                    f"the test is stripped under `python -O`")
                break
