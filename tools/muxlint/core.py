"""muxlint infrastructure: findings, sources, suppressions, baseline.

A *pass* is a callable ``(Source) -> Iterable[Finding]`` registered in
``PASSES`` (each pass module self-registers on import).  The driver
walks the target paths, parses each ``.py`` once, runs every pass, then
filters findings through two suppression channels:

* inline pragma — ``# muxlint: ok[rule] reason`` on the flagged line
  (the reason is mandatory: a bare pragma does not suppress);
* baseline file — JSON entries ``{rule, path, line_text, why}`` matched
  on the *stripped source text* of the flagged line (robust to line
  drift), each with a mandatory ``why``.

Baseline entries that match no current finding are reported as *stale*
and fail the run — accepted exceptions must not outlive the code they
excused.
"""
from __future__ import annotations

import ast
import json
import os
import re
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Tuple

PRAGMA_RE = re.compile(r"#\s*muxlint:\s*ok\[([a-z0-9_,-]+)\]\s*(.*)")


@dataclass(frozen=True)
class Finding:
    rule: str            # pass id, e.g. "layering"
    path: str            # repo-relative file path
    line: int            # 1-based
    message: str
    line_text: str = ""  # stripped source of the flagged line

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class Source:
    """One parsed file, shared by every pass."""
    path: str                      # repo-relative, forward slashes
    text: str
    tree: ast.AST
    lines: List[str] = field(default_factory=list)
    # line -> set of rules a valid inline pragma suppresses ("*" = all)
    pragmas: Dict[int, set] = field(default_factory=dict)

    @classmethod
    def parse(cls, path: str, text: str) -> "Source":
        tree = ast.parse(text, filename=path)
        lines = text.splitlines()
        pragmas: Dict[int, set] = {}
        for i, ln in enumerate(lines, 1):
            m = PRAGMA_RE.search(ln)
            if m and m.group(2).strip():
                # pragma without a justification is ignored on purpose
                pragmas[i] = set(r.strip() for r in m.group(1).split(","))
        return cls(path=path, text=text, tree=tree, lines=lines,
                   pragmas=pragmas)

    def line_text(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def finding(self, rule: str, node_or_line, message: str) -> Finding:
        line = (node_or_line if isinstance(node_or_line, int)
                else getattr(node_or_line, "lineno", 0))
        return Finding(rule=rule, path=self.path, line=line,
                       message=message, line_text=self.line_text(line))

    def suppressed(self, f: Finding) -> bool:
        rules = self.pragmas.get(f.line)
        return bool(rules) and (f.rule in rules or "*" in rules)


Pass = Callable[[Source], Iterable[Finding]]
PASSES: Dict[str, Pass] = {}


def register(name: str) -> Callable[[Pass], Pass]:
    def deco(fn: Pass) -> Pass:
        PASSES[name] = fn
        return fn
    return deco


def all_passes() -> Dict[str, Pass]:
    # import for side effect: each pass module registers itself
    from tools.muxlint import (dead_asserts, jit_hazards,  # noqa: F401
                               layering, purity)
    return dict(PASSES)


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------
def load_baseline(path: str) -> List[dict]:
    """Load and validate the reviewed-exception file.  Every entry
    must carry rule, path, line_text and a non-empty ``why`` — an
    unjustified exception is a config error, not a suppression."""
    with open(path) as f:
        data = json.load(f)
    entries = data["suppressions"] if isinstance(data, dict) else data
    for i, e in enumerate(entries):
        for key in ("rule", "path", "line_text", "why"):
            if not str(e.get(key, "")).strip():
                raise ValueError(
                    f"baseline entry {i} is missing a non-empty "
                    f"{key!r}: {e!r}")
    return entries


def match_baseline(findings: List[Finding], entries: List[dict]
                   ) -> Tuple[List[Finding], List[dict]]:
    """Split ``findings`` against the baseline.  Returns
    ``(unsuppressed, stale_entries)`` — an entry suppresses every
    finding with the same (rule, path, stripped line text); entries
    matching nothing are stale."""
    used = [False] * len(entries)
    out: List[Finding] = []
    for f in findings:
        hit = False
        for i, e in enumerate(entries):
            if (e["rule"] == f.rule and e["path"] == f.path
                    and e["line_text"] == f.line_text):
                used[i] = True
                hit = True
        if not hit:
            out.append(f)
    stale = [e for i, e in enumerate(entries) if not used[i]]
    return out, stale


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def _walk_py(paths: Iterable[str], root: str) -> List[str]:
    files: List[str] = []
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(full) and full.endswith(".py"):
            files.append(full)
        elif os.path.isdir(full):
            for dirpath, dirnames, filenames in os.walk(full):
                dirnames[:] = sorted(d for d in dirnames
                                     if d != "__pycache__"
                                     and not d.startswith("."))
                files.extend(os.path.join(dirpath, fn)
                             for fn in sorted(filenames)
                             if fn.endswith(".py"))
    return files


def lint_paths(paths: Iterable[str], root: str = ".",
               passes: Optional[Dict[str, Pass]] = None
               ) -> Tuple[List[Finding], List[Finding], List[str]]:
    """Run every pass over every ``.py`` under ``paths``.

    Returns ``(kept, pragma_suppressed, errors)`` — ``kept`` still
    needs the baseline filter (``match_baseline``); ``errors`` are
    files that failed to parse (reported, non-fatal: a syntax error is
    the ruff E9 gate's job)."""
    passes = passes if passes is not None else all_passes()
    kept: List[Finding] = []
    suppressed: List[Finding] = []
    errors: List[str] = []
    for full in _walk_py(paths, root):
        rel = os.path.relpath(full, root).replace(os.sep, "/")
        try:
            with open(full, encoding="utf-8") as f:
                src = Source.parse(rel, f.read())
        except (SyntaxError, UnicodeDecodeError) as e:
            errors.append(f"{rel}: {e}")
            continue
        for fn in passes.values():
            for f in fn(src):
                (suppressed if src.suppressed(f) else kept).append(f)
    kept.sort(key=lambda f: (f.path, f.line, f.rule))
    return kept, suppressed, errors
