"""CLI: ``python -m tools.muxlint [paths...]``.

Exit codes: 0 — clean (no unsuppressed findings, no stale baseline
entries); 1 — findings; 2 — stale baseline entries or an invalid
baseline file.  CI gates on 0 (``.github/workflows/ci.yml`` muxlint
job).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from tools.muxlint.core import (all_passes, lint_paths, load_baseline,
                                match_baseline)

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.muxlint",
        description="repo-specific static analysis (layering, clock "
                    "purity, jit hazards, dead asserts)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/directories to analyze (default: src)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="reviewed-exception file (JSON); "
                         "--no-baseline disables")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline file entirely")
    ap.add_argument("--select", default=None,
                    help="comma-separated pass names to run "
                         "(default: all)")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable findings on stdout")
    ap.add_argument("--root", default=".",
                    help="repo root paths are relative to")
    args = ap.parse_args(argv)

    passes = all_passes()
    if args.select:
        want = set(args.select.split(","))
        unknown = want - set(passes)
        if unknown:
            ap.error(f"unknown pass(es): {sorted(unknown)} "
                     f"(have: {sorted(passes)})")
        passes = {k: v for k, v in passes.items() if k in want}

    findings, pragma_suppressed, errors = lint_paths(
        args.paths or ["src"], root=args.root, passes=passes)

    stale = []
    baselined = 0
    if not args.no_baseline and os.path.exists(args.baseline):
        try:
            entries = load_baseline(args.baseline)
        except (ValueError, KeyError, json.JSONDecodeError) as e:
            print(f"muxlint: invalid baseline {args.baseline}: {e}",
                  file=sys.stderr)
            return 2
        n_before = len(findings)
        findings, stale = match_baseline(findings, entries)
        baselined = n_before - len(findings)

    if args.json:
        print(json.dumps({
            "findings": [vars(f) for f in findings],
            "stale_baseline": stale,
            "suppressed_inline": len(pragma_suppressed),
            "suppressed_baseline": baselined,
            "parse_errors": errors,
        }, indent=1))
    else:
        for f in findings:
            print(f.render())
        for e in errors:
            print(f"muxlint: parse error: {e}", file=sys.stderr)
        for s in stale:
            print(f"muxlint: STALE baseline entry (matches nothing — "
                  f"remove it): {s['rule']} {s['path']} "
                  f"{s['line_text']!r}", file=sys.stderr)
        total = len(findings)
        print(f"muxlint: {total} finding{'s' if total != 1 else ''} "
              f"({len(pragma_suppressed)} inline-suppressed, "
              f"{baselined} baselined, {len(stale)} stale baseline "
              f"entr{'ies' if len(stale) != 1 else 'y'})",
              file=sys.stderr)
    if stale:
        return 2
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
