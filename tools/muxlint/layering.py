"""Layering pass: enforce the ARCHITECTURE.md layer DAG.

The graph below is the *declared* architecture — the import edges each
layer is allowed to take, bottom-up (``config`` / ``paging`` at the
base, ``launch`` on top).  The rules the roadmap leans on hardest:

* ``core`` / ``kernels`` / ``models`` must not import ``serving`` or
  ``launch`` (planning and kernels stay runnable without the runtime);
* ``serving`` must not import ``launch`` (the serving layer is a
  library; only the CLI layer may know about CLIs and meshes).

Violations name the edge (``kernels -> serving``) so the fix — move
the shared code down, or invert the dependency — is obvious from the
message.  A module's layer is its first path segment under ``repro/``
(top-level modules like ``config.py`` are their own single-module
layers).
"""
from __future__ import annotations

import ast
from typing import Iterable, Optional

from tools.muxlint.core import Finding, Source, register

# layer -> layers it may import (itself is always allowed)
ALLOWED = {
    "config":  set(),
    "paging":  {"config"},
    "models":  {"config"},
    "configs": {"config", "models"},
    "kernels": {"config", "paging", "models"},
    "core":    {"config", "configs", "models"},
    "train":   {"config", "configs", "models"},
    "serving": {"config", "configs", "paging", "models", "kernels",
                "core"},
    "launch":  {"config", "configs", "paging", "models", "kernels",
                "core", "train", "serving"},
}


def layer_of_path(path: str) -> Optional[str]:
    """Layer of a repo file path, or None when the file is outside
    ``repro`` (tools, tests, benchmarks — unconstrained)."""
    parts = path.replace("\\", "/").split("/")
    if "repro" not in parts:
        return None
    rest = parts[parts.index("repro") + 1:]
    if not rest:
        return None
    head = rest[0][:-3] if len(rest) == 1 and rest[0].endswith(".py") \
        else rest[0]
    return head if head in ALLOWED else None


def layer_of_module(module: str) -> Optional[str]:
    """Layer of a dotted import target (``repro.serving.mux`` ->
    ``serving``)."""
    parts = module.split(".")
    if parts[0] != "repro" or len(parts) < 2:
        return None
    return parts[1] if parts[1] in ALLOWED else None


def _imported_modules(tree: ast.AST) -> Iterable[ast.stmt]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            yield node


@register("layering")
def check(src: Source) -> Iterable[Finding]:
    layer = layer_of_path(src.path)
    if layer is None:
        return
    allowed = ALLOWED[layer] | {layer}
    for node in _imported_modules(src.tree):
        targets = []
        if isinstance(node, ast.Import):
            targets = [a.name for a in node.names]
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.level == 0:
            targets = [node.module]
        for mod in targets:
            tgt = layer_of_module(mod)
            if tgt is not None and tgt not in allowed:
                yield src.finding(
                    "layering", node,
                    f"forbidden layer edge {layer} -> {tgt}: "
                    f"`{mod}` may not be imported from the "
                    f"{layer} layer (ARCHITECTURE.md DAG)")
