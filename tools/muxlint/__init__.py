"""muxlint — repo-specific static analysis for the MuxServe reproduction.

Four AST passes enforce the invariants the test suite can only
spot-check (DESIGN.md §15):

* ``layering``     — the ARCHITECTURE.md layer DAG, from a declared
                     allowed-import graph; violations name the edge.
* ``clock``        — deterministic-replay modules (``serving/``,
                     ``core/``) must not call wall clocks or build
                     unseeded RNGs outside WallClock/probe sites.
* ``jit-hazard``   — host syncs, traced-value branches and ``print``
                     inside jitted step impls (the PR-2 zero-retrace
                     guarantee).
* ``dead-assert``  — tautological or side-effecting assert
                     expressions (an assert that cannot fire, or that
                     changes state when ``-O`` strips it).

Run ``python -m tools.muxlint src`` (CI gates on exit 0).  Accepted
exceptions live either inline (``# muxlint: ok[rule] reason``) or in
the reviewed baseline file ``tools/muxlint/baseline.json`` — both
require a justification, and a baseline entry that no longer matches
any finding fails the run (stale suppressions rot).
"""
from tools.muxlint.core import (Finding, Source, all_passes, lint_paths,
                                load_baseline, match_baseline)

__all__ = ["Finding", "Source", "all_passes", "lint_paths",
           "load_baseline", "match_baseline"]
