"""Docs reference checker (CI gate).

Scans the prose docs (README.md, DESIGN.md, docs/*.md) and fails on:

  * broken intra-repo markdown links — ``[text](path)`` whose target
    does not exist (http/mailto/#anchor and ``../`` escapes are
    skipped);
  * backticked path references (``core/simulator.py``,
    ``benchmarks/fused_tick``, ``kernels/flash_prefill.fused_...``)
    that resolve to no file at the repo root or under ``src/repro/``;
  * backticked dotted module references (``repro.launch.serve``) with
    no matching module under ``src/``;
  * ``python -m <module>`` invocations in fenced code blocks whose
    module cannot be found.

Docs rot silently — a rename like FusedDecodeGroup → FusedGroup (PR 2)
leaves stale pointers everywhere unless something fails loudly.  Run:

  python tools/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = ["README.md", "DESIGN.md", "ROADMAP.md",
             *sorted(str(p.relative_to(ROOT))
                     for p in (ROOT / "docs").glob("**/*.md"))] \
    if (ROOT / "docs").is_dir() else ["README.md", "DESIGN.md", "ROADMAP.md"]

_FENCE = re.compile(r"```.*?```", re.S)
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_INLINE = re.compile(r"`([^`\n]+)`")
_DOTTED = re.compile(r"^repro(\.\w+)+$")
_PATHY = re.compile(r"^[\w./-]+$")
_PY_M = re.compile(r"python(?:3)?\s+-m\s+([\w.]+)")


def _module_exists(dotted: str) -> bool:
    """repro.a.b → src/repro/a/b.py (or package); benchmarks.x,
    tools.x → repo-root packages.  A trailing unresolvable component
    is retried as an attribute of its parent module."""
    parts = dotted.split(".")
    roots = [ROOT / "src", ROOT]
    for root in roots:
        for n in (len(parts), len(parts) - 1):     # maybe last = attr
            if n < 1:
                continue
            p = root.joinpath(*parts[:n])
            if p.with_suffix(".py").is_file() or \
                    (p.is_dir() and (p / "__init__.py").is_file()):
                return True
    return False


def _path_exists(token: str) -> bool:
    """core/simulator.py, benchmarks/fused_tick,
    kernels/paged_attention.fused_paged_decode_attention → a file at
    the repo root or under src/repro/ (last dotted component may be an
    attribute)."""
    cands = [token, token.rstrip("/")]
    if ".py" not in token and "." in token.rsplit("/", 1)[-1]:
        cands.append(token[:token.rindex(".")])    # strip .attribute
    out = []
    for c in cands:
        out += [c, c + ".py"] if not c.endswith(".py") else [c]
    for c in out:
        for base in (ROOT, ROOT / "src" / "repro"):
            p = base / c
            if p.is_file() or p.is_dir():
                return True
    return False


def check_file(rel: str) -> list:
    path = ROOT / rel
    text = path.read_text()
    prose = _FENCE.sub("", text)
    errors = []

    for target in _LINK.findall(prose):
        if target.startswith(("http://", "https://", "#", "mailto:", "../")):
            continue
        t = target.split("#")[0]
        if t and not (path.parent / t).exists() and not (ROOT / t).exists():
            errors.append(f"{rel}: broken link → {target}")

    for tok in _INLINE.findall(prose):
        tok = tok.strip().rstrip(".,;:")
        if _DOTTED.match(tok):
            if not _module_exists(tok):
                errors.append(f"{rel}: unknown module `{tok}`")
        elif "/" in tok and _PATHY.match(tok) and "*" not in tok:
            if not _path_exists(tok):
                errors.append(f"{rel}: unknown path `{tok}`")

    for mod in _PY_M.findall(text):               # incl. fenced examples
        if mod.startswith(("repro", "benchmarks", "tools")) \
                and not _module_exists(mod):
            errors.append(f"{rel}: `python -m {mod}` target missing")
    return errors


def main() -> int:
    errors = []
    for rel in DOC_FILES:
        if not (ROOT / rel).is_file():
            errors.append(f"missing doc file: {rel}")
            continue
        errors.extend(check_file(rel))
    if errors:
        print("\n".join(errors))
        print(f"\n{len(errors)} broken doc reference(s)")
        return 1
    print(f"docs OK: {len(DOC_FILES)} files, all intra-repo references "
          f"resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
